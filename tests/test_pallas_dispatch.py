"""The fused/batched Pallas kernel family behind ``method="pallas"``:

* bit-exactness vs the numpy oracle across primes where strip_rows does
  NOT divide N and m_block does NOT divide N (incl. the paper's N=251),
* forward/inverse round-trips, batched-vs-loop equivalence (one
  pallas_call per stack),
* the hoisted-ladder contract: ladder setup (shift/compare mask
  derivation) happens once per m-block, never inside the Horner loop,
* masked final m-block + lane padding: no wrapped-duplicate garbage,
* overflow-safe accumulators (int64 survives under x64),
* conv routing through the dispatch.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import importlib
D = importlib.import_module("repro.core.dprt")
C = importlib.import_module("repro.core.conv")
from repro.kernels import dprt_pallas, idprt_pallas, pallas_block_spec
from repro.kernels.sfdprt import (_pallas_skew_call, dprt_pallas_raw,
                                  roll_rows_ladder_spec)


def rand_img(n, seed=0, shape=None):
    return np.random.default_rng(seed).integers(
        0, 256, shape or (n, n)).astype(np.int32)


# ---------------------------------------------------------------------------
# exactness on awkward tilings (H does not divide N, M does not divide N)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [5, 7, 13])
@pytest.mark.parametrize("h", [1, 3, None])   # None -> H = N
@pytest.mark.parametrize("mb", [3, 5, 8])
def test_dispatch_forward_inverse_vs_oracle(n, h, mb):
    h = n if h is None else h
    f = rand_img(n, seed=n * 31 + h * 7 + mb)
    ref = D.dprt_oracle_np(f)
    r = D.dprt(jnp.asarray(f), method="pallas", strip_rows=h, m_block=mb)
    np.testing.assert_array_equal(np.asarray(r), ref)
    back = D.idprt(r, method="pallas", strip_rows=h, m_block=mb)
    np.testing.assert_array_equal(np.asarray(back), f)


def test_paper_n251_tuned_blocks():
    """The paper's headline size through the dispatch with tuned blocks."""
    n = 251
    f = rand_img(n, seed=1)
    ref = D.dprt_oracle_np(f)
    r = D.dprt(jnp.asarray(f), method="pallas")
    np.testing.assert_array_equal(np.asarray(r), ref)
    back = D.idprt(r, method="pallas")
    np.testing.assert_array_equal(np.asarray(back), f)


def test_skew_sum_dispatch_matches_ref():
    from repro.kernels import skew_sum_ref
    n = 13
    g = rand_img(n, seed=9)
    for sign in (1, -1):
        a = np.asarray(D.skew_sum(jnp.asarray(g), sign, method="pallas"))
        np.testing.assert_array_equal(
            a, np.asarray(skew_sum_ref(jnp.asarray(g), sign)))


# ---------------------------------------------------------------------------
# batched: one pallas_call == loop of singles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [7, 13])
def test_batched_equals_loop(n):
    fb = rand_img(n, seed=n, shape=(8, n, n))
    rb = np.asarray(D.dprt_batched(jnp.asarray(fb), method="pallas"))
    assert rb.shape == (8, n + 1, n)
    for i in range(8):
        np.testing.assert_array_equal(rb[i], D.dprt_oracle_np(fb[i]))
    back = np.asarray(D.idprt_batched(jnp.asarray(rb.astype(np.int32)),
                                      method="pallas"))
    np.testing.assert_array_equal(back, fb)


def test_batched_kernel_wrappers_accept_2d_and_3d():
    n = 7
    fb = rand_img(n, seed=3, shape=(9, n, n))
    rb = np.asarray(dprt_pallas(jnp.asarray(fb)))
    r0 = np.asarray(dprt_pallas(jnp.asarray(fb[0])))
    np.testing.assert_array_equal(rb[0], r0)
    bb = np.asarray(idprt_pallas(jnp.asarray(rb.astype(np.int32))))
    b0 = np.asarray(idprt_pallas(jnp.asarray(r0.astype(np.int32))))
    np.testing.assert_array_equal(bb[0], b0)
    np.testing.assert_array_equal(bb, fb)


# ---------------------------------------------------------------------------
# hoisted ladder: setup is outside the Horner loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h", [1, 4, 13])
def test_ladder_setup_hoisted_out_of_horner_loop(h):
    """All (amt >> b) & 1 mask derivations (and the index-permute setup)
    run BEFORE the fori_loop: the traced loop body contains no
    shift-right ops, for every strip height."""
    n = 13
    f = jnp.zeros((1, n, n), jnp.int32)
    for impl in ("ladder", "permute"):
        jaxpr = str(jax.make_jaxpr(
            lambda x, hh=h, im=impl: dprt_pallas_raw(
                x, strip_rows=hh, m_block=8, interpret=True,
                step_impl=im))(f))
        loop_tok = next((t for t in ("while[", "scan[") if t in jaxpr), None)
        assert loop_tok is not None, "Horner loop was not traced as a loop"
        _, _, after_loop_start = jaxpr.partition(loop_tok)
        # ALL ladder setup (step + alignment masks, permute indices) is
        # emitted before the loop; the loop body and everything after it
        # must re-derive nothing.
        n_shifts_total = jaxpr.count("shift_right")
        n_shifts_after = after_loop_start.count("shift_right")
        assert n_shifts_after == 0, (
            f"{n_shifts_after} mask derivations inside/after the Horner "
            f"loop (impl={impl}, H={h})")
        # and the total setup is bounded by the two ladders' bit counts
        assert n_shifts_total <= 2 * roll_rows_ladder_spec(n)


def test_ladder_setup_independent_of_strip_height():
    """Setup op count must not scale with H (it is per m-block)."""
    n = 13
    f = jnp.zeros((1, n, n), jnp.int32)
    counts = []
    for h in (1, 13):
        jaxpr = str(jax.make_jaxpr(
            lambda x, hh=h: dprt_pallas_raw(x, strip_rows=hh, m_block=8,
                                            interpret=True,
                                            step_impl="ladder"))(f))
        counts.append(jaxpr.count("shift_right"))
    assert counts[0] == counts[1]


# ---------------------------------------------------------------------------
# masked final m-block + lane padding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [5, 13])
def test_padded_rows_and_lanes_are_zero(n):
    """Wrapped-duplicate direction rows and padded lanes are masked to
    zero -- never computed as (and never mistakable for) useful output."""
    f = rand_img(n, seed=n, shape=(2, n, n))
    out = np.asarray(_pallas_skew_call(
        jnp.asarray(f), sign=1, mode="forward", strip_rows=3, m_block=4,
        interpret=True, lane_pad=True))
    assert out.shape[-1] == 128  # lane axis padded to the Mosaic tile
    for i in range(2):
        np.testing.assert_array_equal(out[i, :n + 1, :n],
                                      D.dprt_oracle_np(f[i]))
        assert (out[i, :, n:] == 0).all()
        assert (out[i, n + 1:, :] == 0).all()


def test_tuning_table_sane():
    for n in [5, 13, 251, 521, 1021, 4099]:
        h, mb = pallas_block_spec(n)
        assert 1 <= h <= n
        assert mb >= 1


# ---------------------------------------------------------------------------
# dtypes / overflow
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [np.uint8, np.int16, np.int32])
def test_integer_dtypes_accumulate_exactly(dtype):
    n = 13
    hi = min(np.iinfo(dtype).max, 255)
    f = np.random.default_rng(7).integers(0, hi, (n, n)).astype(dtype)
    r = np.asarray(D.dprt(jnp.asarray(f), method="pallas"))
    assert r.dtype == np.int32  # accum_dtype_for, not the input dtype
    np.testing.assert_array_equal(r, D.dprt_oracle_np(f.astype(np.int32)))


def test_float32_roundtrip_close():
    n = 7
    f = np.random.default_rng(5).random((n, n)).astype(np.float32)
    r = D.dprt(jnp.asarray(f), method="pallas")
    assert np.asarray(r).dtype == np.float32
    back = np.asarray(D.idprt(r, method="pallas"))
    np.testing.assert_allclose(back, f, rtol=1e-5, atol=1e-4)


@pytest.mark.slow
def test_int64_accumulator_survives_x64(subproc):
    """The fused inverse must keep int64 inputs in int64 (the seed's
    idprt_pallas cast S and R(N, i) to int32 unconditionally)."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core.dprt import dprt_oracle_np, accum_dtype_for
from repro.kernels import dprt_pallas, idprt_pallas
assert accum_dtype_for(jnp.int64) == jnp.int64
n = 13
big = 1 << 40  # row sums overflow int32 by a factor of ~2^22
f = (np.random.default_rng(0).integers(0, 256, (n, n)).astype(np.int64)
     * (big // 256))
r = dprt_pallas(jnp.asarray(f))
assert r.dtype == jnp.int64, r.dtype
np.testing.assert_array_equal(np.asarray(r), dprt_oracle_np(f))
back = idprt_pallas(r)
assert back.dtype == jnp.int64, back.dtype
np.testing.assert_array_equal(np.asarray(back), f)
print("OK int64")
""", devices=1, extra_env={"JAX_ENABLE_X64": "1"})


# ---------------------------------------------------------------------------
# conv routing
# ---------------------------------------------------------------------------
def test_conv_via_pallas_dispatch():
    n = 11
    f = rand_img(n, seed=1)
    g = np.random.default_rng(2).integers(0, 16, (n, n)).astype(np.int32)
    got = np.asarray(C.circ_conv2d_dprt(jnp.asarray(f), jnp.asarray(g),
                                        method="pallas"))
    want = np.asarray(C.circ_conv2d_direct(jnp.asarray(f), jnp.asarray(g)))
    np.testing.assert_array_equal(got, want)


def test_conv_batched_stack_single_kernel():
    n = 7
    fb = rand_img(n, seed=4, shape=(8, n, n))
    g = np.random.default_rng(6).integers(0, 16, (n, n)).astype(np.int32)
    got = np.asarray(C.circ_conv2d_dprt(jnp.asarray(fb), jnp.asarray(g),
                                        method="pallas"))
    for i in range(8):
        want = np.asarray(C.circ_conv2d_direct(jnp.asarray(fb[i]),
                                               jnp.asarray(g)))
        np.testing.assert_array_equal(got[i], want)
