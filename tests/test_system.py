"""End-to-end behaviour tests for the system (deliverable c)."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_radon_service_end_to_end():
    """The paper's workload as a service: phantom batch -> DPRT -> filter in
    the transform domain -> exact inverse."""
    from repro.core import (circ_conv2d_dprt, dprt_batched, idprt_batched)
    from repro.data import radon_images
    imgs = jnp.asarray(radon_images(31, 4, kind="phantom"))
    r = dprt_batched(imgs)
    back = idprt_batched(r)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(imgs))
    # convolution property on a real phantom
    kern = jnp.zeros((31, 31), jnp.int32).at[0, 0].set(2).at[0, 1].set(1)
    out = circ_conv2d_dprt(imgs[0], kern)
    want = 2 * imgs[0] + jnp.roll(imgs[0], 1, axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_train_cli_smoke(tmp_path):
    from repro.launch.train import main
    out = main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "8",
                "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path / "ck")])
    assert np.isfinite(out["last_loss"])


def test_serve_cli_radon_smoke():
    from repro.launch.serve import main
    r = main(["--mode", "radon", "--smoke", "--batch", "4"])
    assert r.shape[0] == 4


def test_serve_cli_lm_smoke():
    from repro.launch.serve import main
    gen = main(["--mode", "lm", "--arch", "qwen3-0.6b", "--smoke",
                "--batch", "2", "--prompt-len", "16", "--gen-tokens", "4"])
    assert gen.shape == (2, 4)


def test_dryrun_artifacts_complete():
    """The committed dry-run matrix covers every (arch x shape x mesh) cell
    and every non-skipped cell compiled."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("dry-run matrix not yet generated")
    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES
    cells = {}
    for f in os.listdir(d):
        with open(os.path.join(d, f)) as fh:
            c = json.load(fh)
        cells[(c["arch"], c["shape"], c["mesh"])] = c
    missing, errors = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ["16x16", "2x16x16"]:
                c = cells.get((arch, shape, mesh))
                if c is None:
                    missing.append((arch, shape, mesh))
                elif c["status"] == "error":
                    errors.append((arch, shape, mesh, c.get("error")))
    assert not missing, f"missing cells: {missing}"
    assert not errors, f"failed cells: {errors}"
    # skips are exactly the documented long_500k full-attention cells
    skips = [k for k, c in cells.items() if c["status"] == "skipped"]
    assert all(k[1] == "long_500k" for k in skips)
    assert len(skips) == 16


@pytest.mark.slow
def test_dryrun_production_mesh_one_cell(subproc):
    """Actually build the 16x16 production mesh (256 fake devices) and
    compile one full-config cell in-process -- deliverable (e) smoke."""
    subproc("""
from repro.launch.dryrun import run_cell
r = run_cell("qwen3_0_6b", "decode_32k", multi_pod=False, outdir="")
assert r["status"] == "ok", r
assert r["roofline"]["chips"] == 256
print("OK", r["roofline"]["dominant"])
""", devices=512, timeout=900,
        extra_env={"REPRO_DRYRUN_DEVICES": "512"})


def test_roofline_parser_units():
    from repro.launch.roofline import parse_collectives, roofline_terms
    hlo = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8]
  %all-gather.2 = bf16[64,1024]{1,0} all-gather(%y), replica_groups=[4,2]<=[8], dimensions={1}
  %reduce-scatter.3 = f32[128]{0} reduce-scatter(%z), replica_groups=[1,8]<=[8]
"""
    c = parse_collectives(hlo)
    assert c["all-reduce"] == 1024 * 512 * 4
    assert c["all-gather"] == 64 * 1024 * 2 // 2
    assert c["reduce-scatter"] == 128 * 4 * 8
    t = roofline_terms(197e12, 819e9, 50e9, 256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    assert t["dominant"] in ("compute", "memory", "collective")


def test_hlo_cost_trip_counts():
    """The trip-count-aware walker fixes XLA's while-body undercount."""
    from repro.launch.hlo_cost import analyze_hlo, compiled_cost_dict

    def scanned(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32)
    compiled = jax.jit(scanned).lower(a, w).compile()
    r = analyze_hlo(compiled.as_text())
    expected = 6 * 2 * 128 * 256 * 256
    assert 0.95 < r["flops"] / expected < 1.1, r
    raw = compiled_cost_dict(compiled).get("flops", 0)
    assert raw < 0.5 * expected  # the bug we are correcting
