"""Blob store (the persistent AOT executable cache's substrate) and
async-checkpointer failure surfacing.

``tests/test_substrate.py`` covers the tree-checkpoint happy paths
(atomic round trip, gc, latest_step, async overlap); this module covers
the keyed-blob layer added for serialized executables -- header/payload
integrity, corruption semantics, key sanitization, atomicity -- plus
the AsyncCheckpointer error path nothing else exercises.
"""
import os

import pytest

from repro.checkpoint import (AsyncCheckpointer, delete_blob, gc_checkpoints,
                              latest_step, list_blobs, load_blob, save_blob)


# ---------------------------------------------------------------------------
# keyed blobs
# ---------------------------------------------------------------------------
def test_blob_roundtrip_with_meta(tmp_path):
    d = str(tmp_path)
    payload = bytes(range(256)) * 3
    path = save_blob(d, "exe_v1", payload, meta={"fingerprint": "cpu1"})
    assert os.path.isfile(path) and path.endswith(".blob")
    data, meta = load_blob(d, "exe_v1")
    assert data == payload
    assert meta == {"fingerprint": "cpu1"}
    # atomic publish: no .tmp leftovers once save_blob returned
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_blob_missing_returns_none(tmp_path):
    assert load_blob(str(tmp_path), "nope") == (None, None)
    assert load_blob(str(tmp_path / "no_dir"), "nope") == (None, None)


def test_blob_overwrite_replaces(tmp_path):
    d = str(tmp_path)
    save_blob(d, "k", b"old", meta={"v": 1})
    save_blob(d, "k", b"new", meta={"v": 2})
    data, meta = load_blob(d, "k")
    assert data == b"new" and meta == {"v": 2}
    assert list_blobs(d) == ["k"]


def test_blob_torn_payload_raises(tmp_path):
    d = str(tmp_path)
    path = save_blob(d, "k", b"x" * 100)
    with open(path, "r+b") as f:          # tear the payload: size mismatch
        f.truncate(os.path.getsize(path) - 10)
    with pytest.raises(ValueError, match="corrupt blob"):
        load_blob(d, "k")


def test_blob_garbage_header_raises(tmp_path):
    d = str(tmp_path)
    path = save_blob(d, "k", b"payload")
    with open(path, "wb") as f:
        f.write(b"\xff" * 64)             # not even a parsable header
    with pytest.raises(ValueError, match="corrupt blob"):
        load_blob(d, "k")


def test_blob_key_sanitized_but_preserved(tmp_path):
    # cache tokens contain '/', ':' etc.; the filename is sanitized but
    # the header keeps the exact key (and guards against collisions on
    # lookup)
    d = str(tmp_path)
    key = "dprt/forward:13x13 int32"
    path = save_blob(d, key, b"abc")
    assert "/" not in os.path.basename(path)[:-len(".blob")]
    data, _ = load_blob(d, key)
    assert data == b"abc"
    assert list_blobs(d) == [key]         # listing reports the true key


def test_list_blobs_skips_corrupt_entries(tmp_path):
    d = str(tmp_path)
    save_blob(d, "good", b"1")
    with open(os.path.join(d, "bad.blob"), "wb") as f:
        f.write(b"\x00garbage")
    assert list_blobs(d) == ["good"]
    assert list_blobs(str(tmp_path / "missing")) == []


def test_delete_blob(tmp_path):
    d = str(tmp_path)
    save_blob(d, "k", b"1")
    assert delete_blob(d, "k") is True
    assert load_blob(d, "k") == (None, None)
    assert delete_blob(d, "k") is False


# ---------------------------------------------------------------------------
# async checkpointer: the error path
# ---------------------------------------------------------------------------
def test_async_checkpointer_surfaces_worker_error(tmp_path):
    # point the checkpointer at a path occupied by a FILE: the
    # background save must fail, and wait() must re-raise that failure
    # instead of swallowing it
    blocked = tmp_path / "not_a_dir"
    blocked.write_text("in the way")
    ck = AsyncCheckpointer(str(blocked))
    ck.save(1, {"x": 1.0})
    with pytest.raises(OSError):
        ck.wait()
    ck.wait()                             # error is consumed, not sticky


def test_gc_and_latest_step_on_missing_dir(tmp_path):
    missing = str(tmp_path / "never_created")
    gc_checkpoints(missing)               # no-op, no raise
    assert latest_step(missing) is None
