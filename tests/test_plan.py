"""Transform-plan layer: backend registry, arbitrary-geometry embedding,
blocked (resource-fitting) execution, auto selection, plan caching."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import importlib
D = importlib.import_module("repro.core.dprt")
C = importlib.import_module("repro.core.conv")
G = importlib.import_module("repro.core.geometry")
PL = importlib.import_module("repro.core.plan")


def rand_img(shape, seed=0, hi=256):
    return np.random.default_rng(seed).integers(0, hi, shape).astype(np.int32)


def embedded_oracle(f):
    """Oracle DPRT of the zero-embedded prime-domain image."""
    geom = G.normalize_geometry(f.shape)
    fp = np.zeros((geom.prime, geom.prime), np.int64)
    fp[: f.shape[0], : f.shape[1]] = f
    return D.dprt_oracle_np(fp)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_has_all_builtin_backends():
    names = PL.available_backends()
    for want in ("gather", "horner", "strips", "pallas", "sharded",
                 "sharded_pallas"):
        assert want in names, names


def test_registry_capability_declarations():
    assert PL.get_backend("pallas").batched_native
    assert PL.get_backend("pallas").takes_m_block
    assert PL.get_backend("strips").needs_strip_rows
    assert PL.get_backend("sharded").mesh_aware
    assert not PL.get_backend("horner").needs_strip_rows
    sp = PL.get_backend("sharded_pallas")
    assert sp.mesh_aware and sp.batched_native and sp.takes_m_block
    assert sp.priority > PL.get_backend("sharded").priority
    rows = {r["name"]: r for r in PL.backend_capabilities()}
    assert rows["pallas"]["batched_native"] and rows["sharded"]["mesh_aware"]
    assert rows["sharded_pallas"]["mesh_aware"]


def test_unknown_method_lists_backends():
    with pytest.raises(ValueError, match="registered backends"):
        PL.get_backend("fftw")
    with pytest.raises(ValueError):
        D.dprt(jnp.asarray(rand_img((5, 5))), method="fftw")


def test_custom_backend_registration_roundtrip():
    calls = []
    horner = PL.get_backend("horner")

    def spy(g, sign, **kw):
        calls.append(g.shape)
        return horner.skew_sum(g, sign, **kw)

    PL.register_backend(PL.Backend(
        name="spy", skew_sum=spy,
        forward=PL._make_forward(spy), inverse=PL._make_inverse(spy)))
    try:
        f = rand_img((7, 7), seed=3)
        out = np.asarray(D.dprt(jnp.asarray(f), method="spy"))
        np.testing.assert_array_equal(out, D.dprt_oracle_np(f))
        assert calls, "registered backend was not dispatched to"
    finally:
        PL._REGISTRY.pop("spy", None)
        PL.plan_cache_clear()


# ---------------------------------------------------------------------------
# method="auto"
# ---------------------------------------------------------------------------
def test_auto_selects_pallas_for_prime_images():
    assert PL.select_backend(251, jnp.int32) == "pallas"
    plan = PL.get_plan((251, 251), "int32", "auto")
    assert plan.method == "pallas" and plan.requested_method == "auto"
    # blocks come from the tuning table
    from repro.kernels.tuning import PALLAS_TUNE
    assert (plan.strip_rows, plan.m_block) == PALLAS_TUNE[251]


def test_auto_falls_back_on_unsupported_dtype():
    # pallas declares int/float only; complex must land elsewhere
    assert PL.select_backend(13, jnp.complex64) == "horner"


def test_auto_transform_is_exact():
    f = rand_img((13, 13), seed=11)
    r = np.asarray(D.dprt(jnp.asarray(f), method="auto"))
    np.testing.assert_array_equal(r, D.dprt_oracle_np(f))
    back = np.asarray(D.idprt(jnp.asarray(r.astype(np.int32)),
                              method="auto"))
    np.testing.assert_array_equal(back, f)


# ---------------------------------------------------------------------------
# arbitrary geometry: embed + bit-exact round trip
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(h=st.integers(1, 14), w=st.integers(1, 14),
       seed=st.integers(0, 10 ** 6))
def test_roundtrip_any_geometry_horner(h, w, seed):
    f = rand_img((h, w), seed)
    plan = PL.get_plan(f.shape, f.dtype, "horner")
    r = plan.forward(jnp.asarray(f))
    assert r.shape == plan.geometry.transform_shape
    back = np.asarray(plan.inverse(r))
    np.testing.assert_array_equal(back, f)


@settings(max_examples=8, deadline=None)
@given(h=st.integers(1, 12), w=st.integers(1, 12),
       seed=st.integers(0, 10 ** 6))
def test_roundtrip_any_geometry_pallas(h, w, seed):
    f = rand_img((h, w), seed)
    plan = PL.get_plan(f.shape, f.dtype, "pallas")
    back = np.asarray(plan.inverse(plan.forward(jnp.asarray(f))))
    np.testing.assert_array_equal(back, f)


@settings(max_examples=6, deadline=None)
@given(b=st.integers(1, 4), h=st.integers(2, 10), w=st.integers(2, 10),
       seed=st.integers(0, 10 ** 6))
def test_roundtrip_batched_any_geometry(b, h, w, seed):
    fb = rand_img((b, h, w), seed)
    for method in ("horner", "pallas"):
        plan = PL.get_plan(fb.shape, fb.dtype, method)
        back = np.asarray(plan.inverse(plan.forward(jnp.asarray(fb))))
        np.testing.assert_array_equal(back, fb, err_msg=method)


def test_forward_matches_embedded_oracle():
    f = rand_img((4, 6), seed=5)
    r = np.asarray(D.dprt(jnp.asarray(f)))       # bare dprt embeds too
    np.testing.assert_array_equal(r, embedded_oracle(f))
    assert r.shape == (8, 7)                     # next_prime(6) = 7


def test_geometry_normalization():
    g = G.normalize_geometry((4, 4))
    assert (g.prime, g.native) == (5, False)
    assert G.normalize_geometry((3, 5)).prime == 5
    g251 = G.normalize_geometry((251, 251))
    assert g251.native and g251.prime == 251
    gb = G.normalize_geometry((8, 3, 5))
    assert gb.batched and gb.batch == 8
    for bad in [(5,), (2, 3, 4, 5), (0, 4)]:
        with pytest.raises(ValueError):
            G.normalize_geometry(bad)


def test_plan_shape_validation():
    plan = PL.get_plan((6, 9), "int32", "horner")
    with pytest.raises(ValueError, match="plan built for"):
        plan.forward(jnp.zeros((9, 6), jnp.int32))
    with pytest.raises(ValueError, match="expects projections"):
        plan.inverse(jnp.zeros((5, 5), jnp.int32))


# ---------------------------------------------------------------------------
# blocked (bounded-memory) execution == whole-image results
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([5, 7, 11, 13]), block=st.integers(1, 13),
       seed=st.integers(0, 10 ** 6))
def test_block_rows_equals_whole_image(n, block, seed):
    f = rand_img((n, n), seed)
    whole = PL.get_plan(f.shape, f.dtype, "horner")
    blocked = PL.get_plan(f.shape, f.dtype, "horner", block_rows=block)
    fj = jnp.asarray(f)
    r_whole = np.asarray(whole.forward(fj))
    r_blocked = np.asarray(blocked.forward(fj))
    np.testing.assert_array_equal(r_blocked, r_whole)
    np.testing.assert_array_equal(
        np.asarray(blocked.inverse(jnp.asarray(r_blocked))), f)


@settings(max_examples=6, deadline=None)
@given(b=st.integers(2, 9), chunk=st.integers(1, 4),
       seed=st.integers(0, 10 ** 6))
def test_block_batch_equals_one_call(b, chunk, seed):
    fb = rand_img((b, 7, 7), seed)
    fj = jnp.asarray(fb)
    for method in ("pallas", "horner"):
        whole = np.asarray(
            PL.get_plan(fb.shape, fb.dtype, method).forward(fj))
        chunked = np.asarray(PL.get_plan(fb.shape, fb.dtype, method,
                                         block_batch=chunk).forward(fj))
        np.testing.assert_array_equal(chunked, whole, err_msg=method)


def test_block_rows_on_embedded_geometry():
    f = rand_img((9, 12), seed=2)
    plan = PL.get_plan(f.shape, f.dtype, "horner", block_rows=4)
    back = np.asarray(plan.inverse(plan.forward(jnp.asarray(f))))
    np.testing.assert_array_equal(back, f)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------
def test_plan_cache_hits():
    PL.plan_cache_clear()
    base = PL.plan_cache_info()
    assert base.currsize == 0
    p1 = PL.get_plan((11, 11), "int32", "horner")
    after_miss = PL.plan_cache_info()
    assert after_miss.misses == base.misses + 1
    p2 = PL.get_plan((11, 11), "int32", "horner")
    after_hit = PL.plan_cache_info()
    assert after_hit.hits == after_miss.hits + 1
    assert p1 is p2                       # cached plan object is reused
    PL.get_plan((11, 11), "int32", "gather")
    assert PL.plan_cache_info().misses == after_miss.misses + 1


def test_transforms_share_the_plan_cache():
    PL.plan_cache_clear()
    f = jnp.asarray(rand_img((7, 7), seed=1))
    D.dprt(f)                              # miss (trace) then cached
    m = PL.plan_cache_info().misses
    D.dprt(f + 1)                          # same shape/dtype/knobs: no trace,
    assert PL.plan_cache_info().misses == m   # and no new plan either


# ---------------------------------------------------------------------------
# sharded backend through the registry (fake multi-device subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_backend_via_registry(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.dprt import dprt, idprt, dprt_oracle_np
from repro.core.plan import get_plan, select_backend
mesh = jax.make_mesh((8,), ("model",))
f = jnp.asarray(np.random.default_rng(0).integers(0, 256, (13, 13)), jnp.int32)
# auto under a mesh picks the highest-priority mesh-aware backend: the
# per-shard fused-kernel path, outranking the legacy "sharded"
assert select_backend(13, jnp.int32, mesh=mesh) == "sharded_pallas"
plan = get_plan(f.shape, f.dtype, "auto", mesh=mesh)
assert plan.method == "sharded_pallas", plan.method
r = np.asarray(plan.forward(f))
assert (r == dprt_oracle_np(np.asarray(f))).all()
back = np.asarray(plan.inverse(jnp.asarray(r.astype(np.int32))))
assert (back == np.asarray(f)).all()
# and through the public entry point
r2 = np.asarray(dprt(f, method="sharded", mesh=mesh))
assert (r2 == r).all()

# a mesh without a "model" axis must still work (axis fallback)
mesh_d = jax.make_mesh((8,), ("data",))
r3 = np.asarray(dprt(f, method="auto", mesh=mesh_d))
assert (r3 == r).all()

# ambient-context resolution must not be pinned by any cache: the same
# shape under auto picks pallas outside the mesh, sharded_pallas inside
# it, and pallas again after the context exits
plain = get_plan(f.shape, f.dtype, "auto")
assert plain.method == "pallas", plain.method
with mesh:
    inside = get_plan(f.shape, f.dtype, "auto")
    assert inside.method == "sharded_pallas", inside.method
    assert (np.asarray(dprt(f, method="auto")) == r).all()
after = get_plan(f.shape, f.dtype, "auto")
assert after.method == "pallas", after.method
assert (np.asarray(dprt(f, method="auto")) == r).all()
print("OK")
""")


# ---------------------------------------------------------------------------
# registry is the single dispatch point (no stray method chains)
# ---------------------------------------------------------------------------
def test_no_per_module_method_chains():
    """The five former dispatch sites must not string-match backend
    names (the registry is the only method->implementation mapping;
    checking for the "auto" sentinel is allowed)."""
    import os
    import re
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    sites = ["core/dprt.py", "core/conv.py", "core/dft.py",
             "kernels/ops.py", "launch/serve.py"]
    pat = re.compile(r"""if\s+method\s*==\s*['"](?!auto['"])""")
    for rel in sites:
        with open(os.path.join(root, rel)) as fh:
            assert not pat.search(fh.read()), \
                f"{rel} still has an if method == <backend> chain"
