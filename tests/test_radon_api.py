"""Tests for the `repro.radon` public operator API (ISSUE 3).

Covers: exact autodiff (grad/jvp vs finite differences and vs the
explicit dense adjoint built from the independent numpy oracle) across
backends, the adjoint/inverse distinction, pytree plans and the
one-trace-per-geometry property, ambient config scopes, the bounded
plan cache, AOT compilation, operator composition, and the legacy
deprecation shims.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import radon
from repro.core import plan as PL
from repro.core.dprt import dprt, dprt_oracle_np, idprt

PRIMES = [5, 7, 13]
BACKENDS = ["gather", "horner", "strips", "pallas"]


def rand_img(shape, lo=0, hi=9, seed=0):
    return np.random.default_rng(seed).integers(lo, hi, shape)


def dense_forward_matrix(n: int) -> np.ndarray:
    """((N+1)N, N^2) forward-DPRT matrix from the independent oracle."""
    cols = []
    for i in range(n * n):
        e = np.zeros(n * n, np.int64)
        e[i] = 1
        cols.append(dprt_oracle_np(e.reshape(n, n)).ravel())
    return np.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# autodiff: grad == explicit adjoint == finite differences, every backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", BACKENDS)
@pytest.mark.parametrize("n", PRIMES)
def test_grad_matches_dense_adjoint_and_fd(n, method):
    op = radon.DPRT((n, n), jnp.float32, method=method)
    A = dense_forward_matrix(n)
    f = jnp.asarray(rand_img((n, n), seed=n), jnp.float32)
    w = jnp.asarray(rand_img((n + 1, n), lo=-4, hi=5, seed=n + 1),
                    jnp.float32)

    loss = lambda x: (op(x) * w).sum()
    grad = np.asarray(jax.grad(loss)(f))

    # explicit adjoint: A^T w, from the oracle matrix (integer-valued
    # float32 arithmetic stays exact at these sizes)
    want = (A.T @ np.asarray(w).ravel()).reshape(n, n)
    np.testing.assert_array_equal(grad, want)

    # finite differences: the op is linear, so a unit step is exact
    for idx in [(0, 0), (n // 2, n - 1)]:
        e = jnp.zeros((n, n), jnp.float32).at[idx].set(1.0)
        fd = loss(f + e) - loss(f)
        assert float(fd) == grad[idx]


@pytest.mark.parametrize("method", BACKENDS)
def test_jvp_is_the_operator_itself(method):
    n = 7
    op = radon.DPRT((n, n), jnp.float32, method=method)
    f = jnp.asarray(rand_img((n, n), seed=3), jnp.float32)
    t = jnp.asarray(rand_img((n, n), lo=-3, hi=4, seed=4), jnp.float32)
    y, tan = jax.jvp(op, (f,), (t,))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(op(f)))
    np.testing.assert_array_equal(np.asarray(tan), np.asarray(op(t)))
    # finite differences, exact by linearity
    np.testing.assert_array_equal(np.asarray(op(f + t) - op(f)),
                                  np.asarray(tan))


def dense_inverse_matrix(n: int) -> np.ndarray:
    """(N^2, (N+1)N) matrix of the paper's explicit inverse formula
    f(i,j) = (1/N)[sum_m R(m, <j - m*i>) - S + R(N, i)], built
    independently of the jax implementation."""
    B = np.zeros((n * n, (n + 1) * n))
    for i in range(n):
        for j in range(n):
            row = i * n + j
            for m in range(n):
                B[row, m * n + ((j - m * i) % n)] += 1
            B[row, 0:n] -= 1            # -S: minus every r[0, d]
            B[row, n * n + i] += 1      # + r[N, i]
    return B / n


@pytest.mark.parametrize("method", BACKENDS)
def test_inverse_grad_matches_dense_inverse_adjoint(method):
    n = 7
    op = radon.DPRT((n, n), jnp.float32, method=method)
    B = dense_inverse_matrix(n)
    r = jnp.asarray(dprt_oracle_np(rand_img((n, n), seed=9)), jnp.float32)
    w = jnp.asarray(rand_img((n, n), lo=-4, hi=5, seed=10), jnp.float32)

    grad = np.asarray(jax.grad(lambda x: (op.inverse(x) * w).sum())(r))
    want = (B.T @ np.asarray(w).ravel()).reshape(n + 1, n)
    np.testing.assert_allclose(grad, want, atol=1e-4, rtol=1e-5)


def test_adjoint_is_transpose_not_inverse():
    n = 5
    op = radon.DPRT((n, n), jnp.float32, method="horner")
    A = np.asarray(op.as_matrix())
    np.testing.assert_array_equal(np.asarray(op.T.as_matrix()), A.T)
    # A^T A = N I + (1 1^T) on images (the paper's frame identity), so
    # the adjoint is emphatically NOT the inverse...
    ata = A.T @ A
    np.testing.assert_array_equal(
        ata, n * np.eye(n * n) + np.ones((n * n, n * n)))
    # ...while inverse . forward IS the identity
    inv_m = np.asarray((op.inverse @ op).as_matrix())
    np.testing.assert_allclose(inv_m, np.eye(n * n), atol=1e-5)
    # and the adjoint pairing <op x, y> == <x, op.T y> holds exactly
    f = jnp.asarray(rand_img((n, n), seed=2), jnp.float32)
    y = jnp.asarray(rand_img((n + 1, n), seed=3), jnp.float32)
    assert float((op(f) * y).sum()) == float((f * op.T(y)).sum())


def test_double_transpose_and_inverse_algebra():
    op = radon.DPRT((5, 5), jnp.float32, method="horner")
    assert op.T.T == op
    assert op.inverse.inverse == op
    assert op.T.inverse == op.inverse.T          # (A^T)^-1 == (A^-1)^T
    assert op.T.inverse.kind == "inverse_adjoint"


@pytest.mark.parametrize("method", ["horner", "pallas"])
def test_batched_grad_matches_per_image(method):
    n, b = 7, 3
    opb = radon.DPRT((b, n, n), jnp.float32, method=method)
    op1 = radon.DPRT((n, n), jnp.float32, method=method)
    fb = jnp.asarray(rand_img((b, n, n), seed=5), jnp.float32)
    wb = jnp.asarray(rand_img((b, n + 1, n), lo=-3, hi=4, seed=6),
                     jnp.float32)
    grad = np.asarray(jax.grad(lambda x: (opb(x) * wb).sum())(fb))
    for i in range(b):
        want = np.asarray(op1.T(wb[i]))
        np.testing.assert_array_equal(grad[i], want)


def test_grad_through_embedded_geometry():
    # non-square, non-prime: embed is linear too, adjoint crops back
    op = radon.DPRT((4, 6), jnp.float32, method="horner")
    p = op.plan.geometry.prime
    f = jnp.asarray(rand_img((4, 6), seed=7), jnp.float32)
    w = jnp.asarray(rand_img((p + 1, p), lo=-3, hi=4, seed=8), jnp.float32)
    grad = np.asarray(jax.grad(lambda x: (op(x) * w).sum())(f))
    A = dense_forward_matrix(p)
    full = (A.T @ np.asarray(w).ravel()).reshape(p, p)
    np.testing.assert_array_equal(grad, full[:4, :6])


@pytest.mark.slow
def test_sharded_backend_grad_exact(subproc):
    """The acceptance bar says EVERY registered backend: the shard_map
    path's grad must hit the exact adjoint too (fake 8-device host)."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro import radon
from repro.core.dprt import dprt_oracle_np
mesh = jax.make_mesh((8,), ("model",))
n = 13
op = radon.DPRT((n, n), jnp.float32, method="sharded", mesh=mesh)
assert op.plan.method == "sharded"
rng = np.random.default_rng(0)
w = jnp.asarray(rng.integers(-4, 5, (n + 1, n)), jnp.float32)
f = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.float32)
g = jax.grad(lambda x: (op(x) * w).sum())(f)
cols = []
for i in range(n * n):
    e = np.zeros(n * n, np.int64); e[i] = 1
    cols.append(dprt_oracle_np(e.reshape(n, n)).ravel())
A = np.stack(cols, axis=1)
want = (A.T @ np.asarray(w).ravel()).reshape(n, n)
assert (np.asarray(g) == want).all(), "sharded grad != explicit adjoint"
# jvp by linearity as well
_, tan = jax.jvp(op, (f,), (f * 0 + 1,))
assert (np.asarray(tan) == np.asarray(op(jnp.ones((n, n), jnp.float32)))).all()
""")


# ---------------------------------------------------------------------------
# pytree plans + one trace per geometry
# ---------------------------------------------------------------------------
def test_plan_is_zero_leaf_pytree():
    plan = radon.get_plan((13, 13), jnp.int32, "horner")
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    assert leaves == []
    assert jax.tree_util.tree_unflatten(treedef, []) is plan


def test_plan_as_jit_argument_traces_once():
    plan = radon.get_plan((11, 11), jnp.int32, "horner")
    traces = []

    @jax.jit
    def run(p, x):
        traces.append(1)
        return p.forward(x)

    f = jnp.asarray(rand_img((11, 11), seed=1), jnp.int32)
    r1 = run(plan, f)
    r2 = run(plan, f + 1)  # same plan object: same treedef, no retrace
    assert len(traces) == 1
    np.testing.assert_array_equal(np.asarray(r1),
                                  dprt_oracle_np(np.asarray(f)))
    # and the SAME geometry fetched again is the same plan -> still 1
    run(radon.get_plan((11, 11), jnp.int32, "horner"), f)
    assert len(traces) == 1


def test_exactly_one_trace_per_geometry():
    op = radon.DPRT((17, 17), jnp.int32, method="horner")
    f = jnp.asarray(rand_img((17, 17), seed=2), jnp.int32)
    op(f)                        # geometry may already be warm from other
    first = op.trace_count       # tests; past here it must never grow
    assert first >= 1
    for k in range(1, 5):
        op(f + k)
    assert op.trace_count == first
    # a second operator over the same geometry shares the trace cache
    op2 = radon.DPRT((17, 17), jnp.int32, method="horner")
    op2(f)
    assert op2.trace_count == first


def test_retrace_guard_fires_and_clears():
    op = radon.DPRT((19, 19), jnp.int32, method="horner")
    f = jnp.asarray(rand_img((19, 19), seed=3), jnp.int32)
    op(f)  # trace outside the guard
    with radon.retrace_guard(max_traces=0):
        op(f + 1)  # cached: ok
    # (shape, dtype, method) triples no other test uses, so these
    # geometries are guaranteed cold regardless of suite order
    with pytest.raises(radon.RetraceError):
        with radon.retrace_guard(max_traces=0):
            radon.DPRT((46, 47), jnp.int16, method="gather")(
                jnp.zeros((46, 47), jnp.int16))
    # the guard stack unwinds: fresh geometries trace fine afterwards
    radon.DPRT((47, 46), jnp.int16, method="gather")(
        jnp.zeros((47, 46), jnp.int16))


def test_serve_path_traces_once_per_geometry():
    """The acceptance scenario: a jitted serve loop shows exactly one
    trace per geometry across repeated calls."""
    op = radon.DPRT((4, 37, 37), jnp.int32, method="pallas")
    imgs = jnp.asarray(rand_img((4, 37, 37), seed=4), jnp.int32)
    base = op.trace_count
    op.inverse(op(imgs))               # one warm pass compiles both paths
    with radon.retrace_guard(max_traces=0):
        for k in range(5):
            r = op(imgs + k)
            back = op.inverse(r)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(imgs + 4))
    assert op.trace_count == base + 1


# ---------------------------------------------------------------------------
# ambient config
# ---------------------------------------------------------------------------
def test_config_scope_resolves_and_nests():
    with radon.config(method="gather", strip_rows=4):
        assert radon.current_config()["method"] == "gather"
        assert radon.DPRT((7, 7), jnp.int32).plan.method == "gather"
        with radon.config(method="strips"):
            p = radon.DPRT((7, 7), jnp.int32).plan
            assert p.method == "strips"
            assert p.strip_rows == 4      # outer scope's knob survives
    assert radon.current_config() == {}
    assert radon.DPRT((7, 7), jnp.int32).plan.method == "pallas"  # auto


def test_config_reaches_legacy_wrappers_and_rejects_unknown():
    f = jnp.asarray(rand_img((7, 7), seed=5), jnp.int32)
    with radon.config(method="gather"):
        r = dprt(f)   # legacy default "horner" is overridden by ambient
    np.testing.assert_array_equal(np.asarray(r),
                                  dprt_oracle_np(np.asarray(f)))
    with pytest.raises(TypeError):
        radon.config(not_a_knob=3)


def test_explicit_kwarg_beats_ambient():
    with radon.config(method="gather"):
        assert radon.DPRT((7, 7), jnp.int32,
                          method="horner").plan.method == "horner"


# ---------------------------------------------------------------------------
# bounded plan cache
# ---------------------------------------------------------------------------
def test_plan_cache_bounded_with_eviction_count():
    radon.plan_cache_clear()
    old = radon.plan_cache_info().maxsize
    try:
        radon.set_plan_cache_maxsize(3)
        base = radon.plan_cache_info().evictions
        for n in [5, 7, 11, 13, 17]:
            radon.get_plan((n, n), jnp.int32, "horner")
        info = radon.plan_cache_info()
        assert info.maxsize == 3
        assert info.currsize <= 3
        assert info.evictions >= base + 2
        # LRU: the most recent geometry is still a hit
        hits = info.hits
        radon.get_plan((17, 17), jnp.int32, "horner")
        assert radon.plan_cache_info().hits == hits + 1
    finally:
        radon.set_plan_cache_maxsize(old)


def test_plan_eviction_drops_jitted_and_aot_state():
    """Bounding the plan cache must bound the (much heavier) per-plan
    jit/AOT caches too, or long-running serve processes still leak."""
    from repro.radon import autodiff as AD
    from repro.radon import operators as OPS
    radon.plan_cache_clear()
    old = radon.plan_cache_info().maxsize
    try:
        radon.set_plan_cache_maxsize(2)
        for n in [5, 7, 11, 13, 17]:
            op = radon.DPRT((n, n), jnp.int32, method="horner")
            op(jnp.zeros((n, n), jnp.int32))
            op.compile()
        live_plans = {k[0] for k in AD._JITTED}
        assert len(live_plans) <= 2
        assert len(OPS._AOT_CACHE) <= 2
    finally:
        radon.set_plan_cache_maxsize(old)


def test_conv_honors_ambient_scope_after_prior_trace():
    """Ambient knobs beyond (method, strip_rows, m_block) participate in
    conv's trace-cache key: a conv traced WITHOUT a scope must not
    replay for a call INSIDE a config(block_batch=...) scope."""
    from repro.core.conv import circ_conv2d_dprt
    fb = jnp.asarray(rand_img((3, 7, 7), seed=11), jnp.int32)
    g = jnp.zeros((7, 7), jnp.int32).at[0, 0].set(1)
    out1 = circ_conv2d_dprt(fb, g)          # traced without a scope
    misses = radon.plan_cache_info().misses
    with radon.config(block_batch=2):
        out2 = circ_conv2d_dprt(fb, g)      # must build chunked plans
    assert radon.plan_cache_info().misses > misses
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_plan_cache_env_var(monkeypatch):
    from repro.core.plan import _env_cache_maxsize
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAXSIZE", "7")
    assert _env_cache_maxsize() == 7
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAXSIZE", "0")
    assert _env_cache_maxsize() is None   # <= 0 => unbounded
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAXSIZE", "nope")
    with pytest.raises(ValueError):
        _env_cache_maxsize()


# ---------------------------------------------------------------------------
# AOT + composition
# ---------------------------------------------------------------------------
def test_aot_compile_cached_and_exact():
    op = radon.DPRT((13, 13), jnp.int32, method="pallas")
    f = jnp.asarray(rand_img((13, 13), seed=6), jnp.int32)
    exe = op.compile()
    assert exe is op.compile()            # cached per geometry
    np.testing.assert_array_equal(np.asarray(exe(f)), np.asarray(op(f)))
    # lower() exposes the standard AOT stages
    assert op.lower().compile()(f).shape == op.shape_out


def test_composition_roundtrip_and_transpose():
    op = radon.DPRT((7, 7), jnp.int32, method="horner")
    f = jnp.asarray(rand_img((7, 7), seed=7), jnp.int32)
    rt = op.inverse @ op
    np.testing.assert_array_equal(np.asarray(rt(f)), np.asarray(f))
    assert rt.shape_in == rt.shape_out == (7, 7)
    # (g @ f).T == f.T @ g.T
    opf = radon.DPRT((7, 7), jnp.float32, method="horner")
    comp = opf.inverse @ opf
    m = np.asarray(comp.T.as_matrix())
    want = np.asarray(opf.T.as_matrix()) @ np.asarray(opf.inverse.T
                                                      .as_matrix())
    np.testing.assert_allclose(m, want, atol=1e-5)
    with pytest.raises(ValueError):
        _ = op @ op                        # (P+1,P) does not feed (H,W)


def test_operator_is_immutable_value_object():
    a = radon.DPRT((7, 7), jnp.int32, method="horner")
    b = radon.DPRT((7, 7), jnp.int32, method="horner")
    assert a == b and hash(a) == hash(b)
    assert a != a.T
    with pytest.raises(AttributeError):
        a.kind = "inverse"


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------
def test_legacy_kwargs_warn_once(monkeypatch):
    import importlib
    # `repro.core.dprt` the attribute is the re-exported function; fetch
    # the module itself to reset the warn-once flag
    D = importlib.import_module("repro.core.dprt")
    monkeypatch.setattr(D, "_LEGACY_KNOB_WARNED", False)
    f = jnp.asarray(rand_img((7, 7), seed=8), jnp.int32)
    with pytest.warns(DeprecationWarning, match="repro.radon.DPRT"):
        dprt(f, method="gather")
    # plain calls never warn; repeated knob calls warn only once
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        dprt(f)
        dprt(f, method="gather")


def test_legacy_and_operator_agree():
    f = jnp.asarray(rand_img((9, 9), seed=9), jnp.int32)  # embeds to 11
    r_legacy = dprt(f)
    op = radon.DPRT(f.shape, f.dtype, method="horner")
    np.testing.assert_array_equal(np.asarray(r_legacy), np.asarray(op(f)))
    rp = dprt(jnp.asarray(rand_img((7, 7), seed=10), jnp.int32))
    np.testing.assert_array_equal(np.asarray(idprt(rp)),
                                  rand_img((7, 7), seed=10))


# ---------------------------------------------------------------------------
# selfcheck
# ---------------------------------------------------------------------------
def test_selfcheck_passes():
    from repro.radon import selfcheck
    assert selfcheck.run(run_bench=False) == 0
