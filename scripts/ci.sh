#!/usr/bin/env bash
# Quick CI gate: the tier-1 test suite, the public-API health smoke,
# the chaos smoke for the fault-tolerant router, and the serving-tier
# perf guard against the committed baseline.
#
#   scripts/ci.sh            # from the repo root
#
# Stays on the quick tier by design: `-m "not slow"` skips the
# forced-host multi-device subprocess tests, the chaos smoke runs with
# `--smoke` (small geometries, short burst), and the perf guard runs
# `--only serve` (the full shoot-out baseline is a longer, separate
# `python -m benchmarks.run --check`).  Each step's failure fails the
# script (set -e), so CI reports the first broken gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="${PYTHONPATH:+$PYTHONPATH:}$PWD/src"

echo "== [1/6] quick-tier tests =="
python -m pytest -x -q -m "not slow" tests

echo "== [2/6] repro.radon.selfcheck =="
python -m repro.radon.selfcheck

echo "== [3/6] router chaos smoke (fault injection, degrade-not-drop) =="
python -m repro.launch.serve --mode service --chaos --smoke

echo "== [4/6] pool chaos smoke (SIGKILL a worker mid-burst, stale locks) =="
python -m repro.launch.serve --mode pool --chaos --smoke --workers 2

echo "== [5/6] serve perf guard (vs committed BENCH_dprt.json) =="
python -m benchmarks.run --check --only serve

echo "== [6/6] recon perf guard (vs committed BENCH_dprt.json) =="
python -m benchmarks.run --check --only recon

echo "== ci.sh: all gates passed =="
