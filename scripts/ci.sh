#!/usr/bin/env bash
# Quick CI gate: the tier-1 test suite, the public-API health smoke,
# the chaos smoke for the fault-tolerant router, and the serving-tier
# perf guard against the committed baseline.
#
#   scripts/ci.sh            # from the repo root
#
# Stays on the quick tier by design: `-m "not slow"` skips the
# forced-host multi-device subprocess tests, the chaos smoke runs with
# `--smoke` (small geometries, short burst), and the perf guard runs
# `--only serve` (the full shoot-out baseline is a longer, separate
# `python -m benchmarks.run --check`).  Each step's failure fails the
# script (set -e), so CI reports the first broken gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="${PYTHONPATH:+$PYTHONPATH:}$PWD/src"

echo "== [1/5] quick-tier tests =="
python -m pytest -x -q -m "not slow" tests

echo "== [2/5] repro.radon.selfcheck =="
python -m repro.radon.selfcheck

echo "== [3/5] router chaos smoke (fault injection, degrade-not-drop) =="
python -m repro.launch.serve --mode service --chaos --smoke

echo "== [4/5] serve perf guard (vs committed BENCH_dprt.json) =="
python -m benchmarks.run --check --only serve

echo "== [5/5] recon perf guard (vs committed BENCH_dprt.json) =="
python -m benchmarks.run --check --only recon

echo "== ci.sh: all gates passed =="
