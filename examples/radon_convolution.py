"""End-to-end driver (the paper's kind of workload): a batched image
filtering service that runs entirely in the DPRT domain.

Pipeline: phantom batch -> forward DPRT -> per-direction 1-D circular
convolution with the filter's projections (the convolution theorem) ->
exact inverse -> integer-identical to direct spatial filtering.

Run:  PYTHONPATH=src python examples/radon_convolution.py [--n 251]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (circ_conv1d_exact, circ_conv2d_direct, dprt_batched,
                        idprt_batched, dprt)
from repro.data import radon_images


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=61, help="prime image size")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    n, b = args.n, args.batch

    imgs = jnp.asarray(radon_images(n, b, kind="phantom"))
    # separable smoothing kernel, integer taps
    kern = jnp.zeros((n, n), jnp.int32)
    kern = kern.at[:3, :3].set(jnp.asarray([[1, 2, 1], [2, 4, 2],
                                            [1, 2, 1]], jnp.int32))

    @jax.jit
    def filter_in_radon_domain(batch_imgs):
        rf = dprt_batched(batch_imgs)              # (B, N+1, N)
        rk = dprt(kern)                            # (N+1, N)
        rc = circ_conv1d_exact(rf, rk[None])       # conv theorem, per m
        return idprt_batched(rc)

    t0 = time.perf_counter()
    out = filter_in_radon_domain(imgs)
    out.block_until_ready()
    dt = time.perf_counter() - t0

    want = circ_conv2d_direct(imgs[0], kern)
    exact = bool((out[0] == want).all())
    print(f"[radon-conv] N={n} batch={b}: {dt * 1e3:.1f} ms "
          f"({b / dt:.1f} img/s), exact vs direct spatial conv: {exact}")
    assert exact
    # every projection of the filtered image still sums to the same total
    total = int(out[0].sum())
    rr = dprt(out[0])
    assert all(int(rr[m].sum()) == total for m in range(n + 1))
    print(f"[radon-conv] invariant check: all {n + 1} projections sum to "
          f"{total} ✓")


if __name__ == "__main__":
    main()
