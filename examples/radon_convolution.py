"""End-to-end driver (the paper's kind of workload): a batched image
filtering service that runs entirely in the DPRT domain, built as a
single composed `repro.radon` operator pipeline.

Pipeline: phantom batch -> forward DPRT -> per-direction 1-D circular
convolution with the filter's projections (the convolution theorem) ->
exact inverse -> integer-identical to direct spatial filtering.  The
batched forward/inverse are ONE cached operator each (one fused
pallas_call per stack under method="auto"/"pallas"), AOT-compiled
before traffic, and a retrace guard asserts the serving loop never
recompiles.

Run:  PYTHONPATH=src python examples/radon_convolution.py [--n 251]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import radon
from repro.core import circ_conv1d_exact, circ_conv2d_direct
from repro.data import radon_images


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=61, help="prime image size")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--method", default="auto",
                    help="any registered backend (see serve --list-backends)")
    args = ap.parse_args()
    n, b = args.n, args.batch

    imgs = jnp.asarray(radon_images(n, b, kind="phantom"))
    # separable smoothing kernel, integer taps
    kern = jnp.zeros((n, n), jnp.int32)
    kern = kern.at[:3, :3].set(jnp.asarray([[1, 2, 1], [2, 4, 2],
                                            [1, 2, 1]], jnp.int32))

    with radon.config(method=args.method):
        fwd = radon.DPRT(imgs.shape, imgs.dtype)      # batched operator
        kop = radon.DPRT(kern.shape, kern.dtype)      # kernel operator
        rk = kop(kern)                                # (N+1, N), once

        @jax.jit
        def filter_in_radon_domain(batch_imgs):
            rf = fwd(batch_imgs)                      # (B, N+1, N)
            rc = circ_conv1d_exact(rf, rk[None])      # conv theorem, per m
            return fwd.inverse(rc)

        # compile before traffic; the loop must then never retrace
        filter_in_radon_domain(imgs).block_until_ready()
        with radon.retrace_guard(max_traces=0):
            t0 = time.perf_counter()
            out = filter_in_radon_domain(imgs)
            out.block_until_ready()
            dt = time.perf_counter() - t0

    want = circ_conv2d_direct(imgs[0], kern)
    exact = bool((out[0] == want).all())
    print(f"[radon-conv] N={n} batch={b} method={fwd.plan.method}: "
          f"{dt * 1e3:.1f} ms ({b / dt:.1f} img/s), "
          f"exact vs direct spatial conv: {exact}")
    assert exact
    # every projection of the filtered image still sums to the same total
    total = int(out[0].sum())
    single = radon.DPRT(out[0].shape, out[0].dtype)
    rr = single(out[0])
    assert all(int(rr[m].sum()) == total for m in range(n + 1))
    print(f"[radon-conv] invariant check: all {n + 1} projections sum to "
          f"{total} ✓")
    # and the adjoint is available for learned-reconstruction workloads
    fsingle = radon.DPRT(out[0].shape, jnp.float32)
    g = jax.grad(lambda x: fsingle(x).sum())(out[0].astype(jnp.float32))
    assert (g == fsingle.T(jnp.ones(fsingle.shape_out, jnp.float32))).all()
    print("[radon-conv] jax.grad through the pipeline == explicit adjoint ✓")


if __name__ == "__main__":
    main()
