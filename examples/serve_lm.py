"""Batched LM serving: prefill a prompt batch, then stream greedy tokens
against the KV cache (the decode_32k shape's code path at demo scale).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()
    serve_main(["--mode", "lm", "--arch", args.arch, "--smoke",
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--gen-tokens", str(args.gen_tokens)])


if __name__ == "__main__":
    main()
