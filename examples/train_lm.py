"""Train a ~100M-parameter LM for a few hundred steps on the framework's
full path (pjit-able step, AdamW, checkpointing, restart-safe).

Defaults are CPU-sized; on a real pod pass --mesh and a full --arch.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

from repro.configs import get_smoke_config
from repro.models.config import ModelConfig
from repro.runtime import Trainer, TrainerConfig


def build_100m() -> ModelConfig:
    # ~100M params: 8 layers, d=512, llama-style
    return ModelConfig(
        name="demo-100m", family="dense", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, chunk_kv=256, chunk_q=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true",
                    help="use the tinyllama smoke config instead of 100M")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    mcfg = get_smoke_config("tinyllama_1_1b") if args.tiny else build_100m()
    tcfg = TrainerConfig(batch_size=args.batch, seq_len=args.seq,
                         steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=max(args.steps // 4, 1), lr=3e-4,
                         log_every=max(args.steps // 20, 1))
    out = Trainer(mcfg, tcfg).run()
    first, last = out["log"][0]["loss"], out["last_loss"]
    print(f"[train_lm] {mcfg.name}: {args.steps} steps, "
          f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
