"""Sinogram inpainting: reconstruction from partial projection data.

The workload behind `radon.solve`: a detector drops whole projection
directions (dead rows in the (P+1, P) sinogram), and the exact inverse
transform -- which needs every direction -- no longer applies.  The
demo reconstructs a phantom three ways:

* zero-filled inverse  -- feed the masked sinogram straight to the
  exact inverse (what you get without a solver: badly wrong, the
  missing directions alias across the whole image);
* masked CG           -- `radon.solve(op, b, mask=...)`: least squares
  over the masked operator, each normal-equation application ONE fused
  projection-pipeline launch;
* Sherman-Morrison    -- the full-data control: `radon.solve` with no
  mask is a non-iterative closed form (`iterations == 0`) matching the
  exact inverse.

Run:  PYTHONPATH=src python examples/reconstruction.py [--n 61]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.data import phantom_image


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=61, help="prime image size")
    ap.add_argument("--drop", type=int, default=4,
                    help="number of projection directions to remove")
    ap.add_argument("--method", default="auto",
                    help="any registered backend (see serve --list-backends)")
    args = ap.parse_args()
    n = args.n

    img = phantom_image(n, seed=0)
    op = radon.DPRT((n, n), jnp.int32, method=args.method)
    sino = op(jnp.asarray(img))                      # (N+1, N) projections
    scale = float(np.abs(img).max())

    # the detector fault: whole directions go dark
    rng = np.random.default_rng(1)
    missing = rng.choice(n + 1, size=args.drop, replace=False)
    mask = radon.direction_mask(n, missing)
    b = mask * sino.astype(jnp.float32)              # what was measured

    # control 1: full data needs no iteration at all
    full = radon.solve(op, sino)
    print(f"[recon] full data, Sherman-Morrison closed form: "
          f"iterations={int(full.iterations)}, max err "
          f"{np.abs(np.asarray(full.image) - img).max():.2e}")

    # control 2: pretending the holes are zeros corrupts everything
    naive = np.asarray(op.inverse(b.astype(op.inverse.dtype_in)))
    naive_err = np.abs(naive - img).max() / scale
    print(f"[recon] zero-filled inverse with {args.drop} directions "
          f"missing: rel err {naive_err:.1%}")

    # the solver: least squares over the masked operator
    res = radon.solve(op, b, mask=mask, tol=1e-7, maxiter=200)
    rec_err = np.abs(np.asarray(res.image) - img).max() / scale
    hist = np.asarray(res.residual_norms)
    hist = hist[~np.isnan(hist)]
    print(f"[recon] masked CG: iterations={int(res.iterations)}, "
          f"converged={bool(res.converged)}, rel err {rec_err:.1%}")
    print("[recon] residual history: "
          + " ".join(f"{h:.1e}" for h in hist[:8])
          + (" ..." if len(hist) > 8 else ""))
    # dropping directions leaves the system underdetermined, so the
    # min-norm least-squares image cannot match the phantom exactly --
    # but it is data-consistent (residual ~1e-8) and several times
    # closer than pretending the holes are zeros
    assert rec_err < naive_err / 2, \
        "solver must clearly beat zero-filling"
    print(f"[recon] OK: masked least squares is "
          f"{naive_err / rec_err:.1f}x closer than zero-filling")


if __name__ == "__main__":
    main()
