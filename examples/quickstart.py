"""Quickstart: the `repro.radon` operator API in ten lines each.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.core import (circ_conv2d_dprt, dft2_reference, dft2_via_dprt,
                        next_prime, pareto)


def main():
    # 1. one operator per geometry: forward + exact inverse
    rng = np.random.default_rng(0)
    n = 31
    img = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    op = radon.DPRT(img.shape, img.dtype)  # method="auto" -> fused pallas
    r = op(img)                            # (N+1, N), exact int32
    assert (op.inverse(r) == img).all()
    print(f"1. DPRT round-trip on {n}x{n} via {op.plan.method}: bit-exact ✓ "
          f"(projections sum to {int(r[0].sum())} = total pixel sum)")

    # 2. any geometry: non-prime/rectangular images embed into the next
    #    prime and the SAME operator's inverse crops back exactly
    rect = jnp.asarray(rng.integers(0, 256, (40, 57)), jnp.int32)
    op_r = radon.DPRT(rect.shape, rect.dtype)
    assert (op_r.inverse(op_r(rect)) == rect).all()
    print(f"2. (40, 57) image -> prime P={op_r.plan.geometry.prime} "
          "projections -> cropped back bit-exact ✓")

    # 3. ambient config scopes replace per-call kwarg plumbing
    with radon.config(method="strips", strip_rows=8):
        assert (radon.DPRT(img.shape, img.dtype)(img) == r).all()
    print("3. radon.config(method='strips', strip_rows=8): same bits ✓")

    # 4. the adjoint is first-class (op.T != op.inverse) and jax.grad
    #    through ANY backend -- including pallas -- hits it exactly
    opf = radon.DPRT((n, n), jnp.float32, method="pallas")
    w = jnp.asarray(rng.integers(0, 9, opf.shape_out), jnp.float32)
    grad = jax.grad(lambda x: (opf(x) * w).sum())(img.astype(jnp.float32))
    assert (grad == opf.T(w)).all()
    print("4. jax.grad through the fused pallas kernel == explicit "
          "adjoint ✓ (differentiable Radon layers)")

    # 5. AOT serving: compile once per geometry, then zero retraces
    exe = op.compile()
    with radon.retrace_guard(max_traces=0):
        for _ in range(3):
            exe(img)
    print("5. op.compile(): AOT executable, zero retraces under guard ✓")

    # 6. operator composition: a whole DPRT-domain pipeline as one object
    roundtrip = op.inverse @ op
    assert (roundtrip(img) == img).all()
    print("6. (op.inverse @ op)(img) == img: composition ✓")

    # 7. exact integer convolution + the slice-theorem DFT still ride on
    #    the same plans underneath
    kernel = jnp.zeros((n, n), jnp.int32).at[:3, :3].set(1)
    out = circ_conv2d_dprt(img, kernel)
    err = float(jnp.max(jnp.abs(dft2_via_dprt(img) - dft2_reference(img))))
    print(f"7. exact 3x3 box filter (sum={int(out.sum())} = 9x image sum) "
          f"and 2-D DFT via N+1 FFTs (max err {err:.2e}) ✓")

    # 8. the paper's Pareto front + prime-vs-pow2 padding argument
    front = pareto.pareto_front(251)
    print(f"8. Pareto strip heights for N=251: {front[:6]}... and linear "
          f"conv 251+16-1=266 -> prime {next_prime(266)} (vs 512 FFT) ✓")


if __name__ == "__main__":
    main()
