"""Quickstart: the DPRT public API in ten lines each.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (circ_conv2d_dprt, dft2_reference, dft2_via_dprt,
                        dprt, idprt, next_prime, pareto)
from repro.kernels import dprt_pallas


def main():
    # 1. forward + exact inverse on a prime-sized integer image
    rng = np.random.default_rng(0)
    n = 31
    img = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    r = dprt(img)                          # (N+1, N), exact int32
    back = idprt(r)
    assert (back == img).all()
    print(f"1. DPRT round-trip on {n}x{n}: bit-exact ✓ "
          f"(projections sum to {int(r[0].sum())} = total pixel sum)")

    # 2. the paper's scalable strip decomposition (choose H for your VMEM)
    for h in [2, 8, n]:
        assert (dprt(img, method="strips", strip_rows=h) == r).all()
    print("2. strip decomposition H∈{2,8,N}: identical results ✓")

    # 3. the Pallas TPU kernel (interpret mode on CPU)
    rk = dprt_pallas(img, strip_rows=8, m_block=8)
    assert (rk == r).all()
    print("3. Pallas SFDPRT kernel == oracle ✓")

    # 4. exact integer convolution through the transform domain
    kernel = jnp.zeros((n, n), jnp.int32).at[:3, :3].set(1)
    out = circ_conv2d_dprt(img, kernel)
    print(f"4. exact 3x3 box filter via DPRT: sum={int(out.sum())} "
          f"(= 9x image sum: {int(img.sum()) * 9}) ✓")

    # 5. 2-D DFT by the discrete Fourier-slice theorem
    err = float(jnp.max(jnp.abs(dft2_via_dprt(img) - dft2_reference(img))))
    print(f"5. 2-D DFT via N+1 1-D FFTs: max err vs fft2 = {err:.2e} ✓")

    # 6. the paper's Pareto front: pick H for your budget
    front = pareto.pareto_front(251)
    print(f"6. Pareto-optimal strip heights for N=251: {front[:8]}... "
          f"({len(front)} points; H=84 runs "
          f"{pareto.cycles_systolic(251) / pareto.cycles_sfdprt(251, 84):.0f}x "
          "faster than the systolic baseline)")

    # 7. prime padding beats power-of-two padding for linear convolution
    print(f"7. linear conv 251+16-1=266 -> pad to prime {next_prime(266)} "
          "(vs 512 for an FFT) ✓")


if __name__ == "__main__":
    main()
