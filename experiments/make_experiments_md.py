"""Generates EXPERIMENTS.md from the dry-run artifacts + perf logs."""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}


def load(dirname):
    cells = {}
    for p in sorted(glob.glob(os.path.join(ROOT, dirname, "*.json"))):
        c = json.load(open(p))
        cells[(c["arch"].split("+")[0], c["shape"], c["mesh"])] = c
    return cells


def fmt_s(x):
    return f"{x:.3g}"


def roofline_table(cells, mesh="16x16"):
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), c in sorted(cells.items()):
        if m != mesh or c["status"] != "ok":
            continue
        t = c["roofline"]
        ideal = c["model_flops"] / (t["chips"] * HW["peak_flops"])
        if c.get("decode_ideal"):
            frac = c["decode_ideal"]["fraction_of_modeled"]
            fr = f"{100 * frac:.1f}% (mem)"
        else:
            fr = f"{100 * ideal / t['step_s_lower_bound']:.2f}% (comp)"
        lines.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['dominant']} | {c['useful_flops_ratio']:.3f} | {fr} |")
    return "\n".join(lines)


def delta_table(base, opt, mesh="16x16"):
    lines = ["| arch | shape | bound before (s) | bound after (s) | Δ |",
             "|---|---|---|---|---|"]
    for key in sorted(base):
        arch, shape, m = key
        if m != mesh:
            continue
        b, o = base[key], opt.get(key)
        if b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        bb = b["roofline"]["step_s_lower_bound"]
        oo = o["roofline"]["step_s_lower_bound"]
        d = (oo - bb) / bb * 100
        lines.append(f"| {arch} | {shape} | {fmt_s(bb)} | {fmt_s(oo)} | "
                     f"{d:+.1f}% |")
    return "\n".join(lines)


def dryrun_summary(cells):
    ok = sum(1 for c in cells.values() if c["status"] == "ok")
    sk = sum(1 for c in cells.values() if c["status"] == "skipped")
    er = sum(1 for c in cells.values() if c["status"] == "error")
    meshes = sorted({m for _, _, m in cells})
    compile_max = max((c.get("compile_s", 0) for c in cells.values()
                       if c["status"] == "ok"), default=0)
    return ok, sk, er, meshes, compile_max


def mem_table(cells, mesh="2x16x16"):
    lines = ["| arch | shape | args GB/dev | temps GB/dev | "
             "collective count |", "|---|---|---|---|---|"]
    for (arch, shape, m), c in sorted(cells.items()):
        if m != mesh or c["status"] != "ok":
            continue
        mem = c.get("memory", {})
        a = mem.get("argument_bytes", 0) / 2 ** 30
        t = mem.get("temp_bytes", 0) / 2 ** 30
        cnt = c["collectives"].get("flat_module", {}).get("count", "-")
        lines.append(f"| {arch} | {shape} | {a:.2f} | {t:.2f} | {cnt} |")
    return "\n".join(lines)


def main():
    base = load("experiments/dryrun_baseline")
    opt = load("experiments/dryrun_opt")
    ok_b, sk_b, er_b, meshes_b, cmp_b = dryrun_summary(base)
    ok_o, sk_o, er_o, meshes_o, cmp_o = dryrun_summary(opt)

    md = f"""# EXPERIMENTS

All artifacts regenerate with:

```
PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both --outdir experiments/dryrun_opt
PYTHONPATH=src python -m benchmarks.run
PYTHONPATH=src pytest tests/
```

Hardware model (TPU v5e targets): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.  This container is CPU-only: all TPU numbers are
*derived from compiled dry-run artifacts* (see Methodology); all DPRT
service numbers are *measured wall-clock on this host*.

## §Reproduction vs the paper's own claims

The paper's analytical models (Tables I-III, eq. 11, Fig. 22) are
implemented in `repro.core.pareto` and pinned by tests to the quoted
numbers — the faithful-reproduction gate:

| paper claim | reproduced value | test |
|---|---|---|
| FDPRT N=251 computes in 511 cycles | `cycles_fdprt(251) == 511` | test_paper_cycle_pins |
| systolic N=251: 63,253 cycles | `cycles_systolic(251) == 63253` | test_paper_cycle_pins |
| systolic N=251: 516,096 flip-flops | `flipflops_systolic(251,8) == 516096` | test_paper_resource_pins |
| H=84 runs ~36x faster than systolic with ~25% fewer FFs | 35.6x at 74.7% of the FFs | test_paper_resource_pins |
| Pareto front over H (eq. 11) monotone in cycles/resources | verified programmatically | test_pareto_front_monotone |
| exact integer reconstruction | `idprt(dprt(f)) == f` bit-exact, all methods + Pallas kernel, property-tested | test_dprt_core / test_kernels |
| DPRT convolution avoids float FFT | integer-exact circular & linear conv vs direct oracle | test_conv_dft |
| prime padding beats pow2 (Sec. I) | 269 vs 512 for 251+16-1 | test_prime_padding_beats_pow2 |

## §Dry-run

Production meshes built by `repro.launch.mesh.make_production_mesh`:
single-pod `(16,16)=("data","model")` = 256 chips, multi-pod
`(2,16,16)=("pod","data","model")` = 512 chips, on 512 forced host
devices.  Every (architecture x input-shape x mesh) cell is
`jit(...).lower(**input_specs).compile()`d with full parameter, optimizer
(train), KV/state-cache (decode) shardings; `memory_analysis()` and
`cost_analysis()` recorded per cell in `experiments/dryrun*/`.

* Baseline matrix: **{ok_b} ok / {sk_b} skipped / {er_b} errors** over meshes {meshes_b}.
* Optimized matrix: **{ok_o} ok / {sk_o} skipped / {er_o} errors**; max compile time {max(cmp_b, cmp_o):.0f}s.
* The 16 skips are exactly the documented `long_500k` x full-attention
  cells (sub-quadratic mixing required; runs for mamba2 + recurrentgemma).
* train_4k lowers `train_step` (fwd+bwd+AdamW, ZeRO-1 moments), prefill
  lowers `prefill` (logits + cache), decode/long lower `serve_step` (one
  token against the cache, cache donated).

Multi-pod (2x16x16) per-device memory & collective presence (proves the
`pod` axis shards; full numbers in the JSONs):

{mem_table(opt if opt else base)}

Caveat: XLA:CPU's `memory_analysis` is a loose upper bound (host
allocator, no TPU liveness/rematerialization packing).  Cells whose
temp bound exceeds 16 GB/chip (the two 236B-class MoE trains) fit on
real v5e via the framework's gradient accumulation (microbatching) —
`optim.accumulate_grads` — or a larger `model` axis; all other cells are
comfortably under budget even by the pessimistic bound.

## §Roofline (single-pod 16x16, per assignment)

Methodology: `compiled.cost_analysis()` on XLA:CPU counts `while` bodies
once, so scanned stacks (layers, KV chunks, SSD chunks) are undercounted
by their trip counts (verified 8x for an 8-step scan).  We therefore walk
the optimized HLO with trip-count multiplication (`repro.launch.hlo_cost`,
validated to ratio 1.000 on known matmuls/scans): dot-MACs+elementwise
FLOPs; an HBM model charging operand+result bytes at fusion boundaries
(window reads like dynamic-slice/gather charge the window, not the
buffer); collective operand bytes with the same multipliers (all-gather =
result/group, reduce-scatter = result*group).  Raw `cost_analysis`
numbers are retained in the JSONs for comparison.

`MODEL/HLO flops` = (6·N·D train, 2·N·D inference; N_active for MoE) /
compiled HLO FLOPs — the useful-compute fraction that exposes
remat/rectangle/capacity waste.  For decode cells the roofline fraction
is bytes-based (reading each param shard + the cache once is the floor);
for train/prefill it is compute-based.

### Baseline (paper-faithful substrate: chunked attention, global MoE dispatch)

{roofline_table(base)}

### Optimized (beyond-paper: triangular-segmented attention, group-local MoE dispatch, ckv=4096)

{roofline_table(opt)}

### Baseline -> optimized, step-time lower bound (max of the three terms)

{delta_table(base, opt)}

### Reading the table

* Every cell is memory-dominated under this model except the MoE trains
  (collective-dominated at baseline).  The three-term model says: at
  these global batch sizes the fleet is HBM-limited, so the §Perf work
  drives bytes (and the collective bytes hiding inside scan bodies) down.
* decode fractions against the bytes floor show GQA caches at ~0.4-2.8%
  of ideal: the decode step's chunked-attention scan re-touches f32
  score/accumulator tiles; a fused attention kernel (VMEM-resident
  softmax state) is the identified next step and the reason real serving
  stacks use one.
* `long_500k` for the SSM/hybrid archs costs the same as `decode_32k`
  modulo batch (O(1) state) — the table's strongest argument for
  state-space decode at 500k context.
* useful-flops > 1 is impossible; values near 1 (mamba prefill 0.95)
  mean almost no wasted compute; low values localize waste (phi3 train
  0.41 = full-remat recompute + causal-rectangle waste; qwen3-0.6b
  prefill 0.14 = small model swamped by attention scores).

## §Perf — hypothesis -> change -> measure log

Three hillclimbed cells per assignment: (A) the paper-representative
DPRT service (measured wall-clock on this host), (B) the most
collective-bound cell `qwen3-moe-235b train_4k`, (C) the worst
roofline-fraction non-decode cell `phi3-medium-14b prefill_32k`.
Baselines are the paper-faithful implementations; optimized variants are
config-selectable (`attn_impl`, `moe_dispatch`) with the baseline kept.

### Cell A — DPRT service, N=251 (measured, CPU host)

| iteration | hypothesis | result | verdict |
|---|---|---|---|
| A0 gather (systolic analog) | baseline: per-direction shear via gather | 52-276 ms/img across host-load states (final uncontended: 51.6 ms) | baseline |
| A1 Horner shift-add (the paper's dataflow) | reuse of partial sums + single (N,N) gather/step keeps the 252 KB accumulator cache-resident; predict >5x | **14.8 ms — 3.5x-16.7x vs A0 depending on host load** (final bench: 3.5x) | confirmed |
| A2 scan unroll 2/4/8 | lower loop overhead, cross-step fusion | 2.4-3x *slower* | refuted — unrolled gathers defeat XLA CPU fusion |
| A3 binary roll-select ladder (the TPU kernel's trick) on CPU | replace gather with 8 rot+select | 19x slower | refuted on CPU; CPU gathers of contiguous rows are fast. Kept in the Pallas kernel where per-sublane variable shifts don't exist — the hardware-adaptation split is now *measured*, not assumed |
| A4 doubled-buffer dynamic-slice (CLS-register literal) | contiguous slices beat gather | 4.6x slower | refuted |
| A5 batched service vmap->lax.map | vmapped scan broadcasts gather indices, blowing L2; sequential map should hit the Bx-single ideal | 11 img/s -> **63.3 img/s** (bench_output `dprt_impl/batched16`) | confirmed; `dprt_batched(batch_impl='auto')` picks map on CPU, vmap on TPU |

Stop: A2-A4 were three consecutive negative results on the single-image
path; the confirmed wins are A1 and A5 (5.7x service throughput).
Wall-clock ratios on this shared host vary with load; the official
numbers are the ones in `bench_output.txt`.  The TPU-side block-size trade (H x M VMEM tiling)
is swept analytically in `benchmarks/fig19_20_pareto.py` — the paper's
Pareto front re-derived for VMEM bytes vs VPU ops.

### Cell B — qwen3-moe-235b-a22b train_4k (dominant term: collective 303 s)

| iteration | hypothesis | comp / mem / coll (s/dev) | bound | verdict |
|---|---|---|---|---|
| B0 baseline | global-capacity scatter dispatch | 16.4 / 291.6 / **302.6** | 302.6 | collective-bound |
| B1 remat=dots | collectives are bwd remat replays; saving dot outputs avoids them | 16.1 / 304.5 / 300.6 | 304.5 | **refuted** — collectives are the dispatch itself; saving dots only added memory |
| B2 grouped dispatch (`moe_dispatch=grouped`) | HLO shows 10.7 TB/dev of *all-reduce*: XLA realizes the global scatter-add as a full expert-buffer all-reduce over 32-way DP. Per-DP-shard capacity pools keep scatter/combine shard-local | 16.4 / 278.4 / 205.4 | 278.4 | confirmed, −32% collective |
| B3 + capacity_factor 1.0 | −20% dispatch payload + expert FLOPs | 13.6 / 240.0 / 176.2 | 240.0 | confirmed |
| B4 + segmented attention (from cell C) | attention share of bytes/collectives | 13.2 / **225.8** / 176.5 | 225.8 | confirmed |
| B5 + remat=dots (recheck) | with dispatch fixed, dots may now help | 12.9 / 238.5 / 174.5 | 238.5 | refuted (+5.6%) |

Net: step-time lower bound **302.6 -> 225.8 s/step (−25%)**; the
collective term fell **302.6 -> 176.5 (−42%)**.  Remaining: 3.2 TB/dev
all-to-all + 5.4 TB/dev all-reduce across the 94-layer fwd+bwd — next
lever is token-permute all-to-all dispatch (ragged_dot) instead of
scatter, noted as future work.

### Cell C — phi3-medium-14b prefill_32k (worst compute fraction, memory-bound 77.3 s)

| iteration | hypothesis | comp / mem / coll (s/dev) | bound | verdict |
|---|---|---|---|---|
| C0 baseline | chunked online-softmax attention | 3.09 / **77.3** / 19.6 | 77.3 | memory-bound |
| C1 chunk_kv 1024->4096 | fewer accumulator round-trips | 3.09 / 73.8 / 19.8 | 73.8 | partially confirmed (−4.5%) |
| C2 q-chunked two-level flash | move the (B,H,Sq,hd) accumulator out of the KV loop | 5.09 / 99.1 / 4.7 | 99.1 | **refuted for memory** (KV re-read per q-chunk dominates) — but −76% collective, kept as an option |
| C3 triangular segmentation x4 (`attn_impl=segmented`) | the fully-masked upper-triangle KV chunks are ~44% of score traffic *and* FLOPs; static segments never compute them | 3.39 / 56.3 / 3.05 | 56.3 | confirmed, −27% |
| C4 segments x8 | finer triangle, (n+1)/2n -> 56% of rectangle | 3.11 / 51.6 / 3.77 | 51.6 | confirmed |
| C5 x8 + chunk_kv 4096 | combine C1+C4 | 2.83 / **43.0** / 4.3 | 43.0 | confirmed |

Net: **77.3 -> 43.0 s/step (−44%)**; collective −78%; compute −8%
(rectangle waste removed).  The same setting improves every causal
self-attention cell (see the delta table above) and is the new default;
the baseline stays selectable (`attn_impl=chunked`).

### Fleet-level effect

The optimized defaults (segmented attention + grouped MoE dispatch +
ckv=4096) were re-lowered over the full 40-cell matrix on both meshes —
the "baseline -> optimized" table above is the before/after record.
Train/prefill cells improved up to 87% (tinyllama prefill −87%,
internvl2/minitron/qwen3 prefills −84..85%, phi3 train −59%) with no
regressions; sub-second decode cells move within ±15%, which is the
model's sensitivity to XLA fusion-boundary choices (decode code paths
are not touched by these flags) — noted, not chased.

## §Scale-out notes (1000+ nodes)

* DP over `pod x data` (+ZeRO-1 moments), TP/EP over `model`; the
  multi-pod mesh only adds a `pod` axis to the batch rules, shown
  compiling for all cells — scaling out = growing `pod`.
* Fault tolerance: atomic rename checkpoints + async writer + keep-k GC;
  restart-from-latest loop (tested, incl. mid-async-write crashes);
  elastic restore re-shards host-agnostic checkpoints onto a different
  mesh (tested 2x4 -> 4x2); straggler watchdog flags slow steps against
  a rolling median (hook point for re-slicing).
* Distributed optimization: int8 stochastic-rounding gradient
  compression with an exact int32 shard_map psum (error <0.4%, tested),
  gradient accumulation, compute/comm overlap left to XLA latency hiding
  (collective-permute chains visible in the HLO).
* The DPRT service itself scales by the paper's own decomposition:
  strips = devices (`shard_map` partial DPRT + psum/psum_scatter ==
  MEM_OUT over ICI), batch over `pod x data` with zero collectives.
"""
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(md)
    print("wrote EXPERIMENTS.md",
          f"(baseline cells={len(base)}, optimized cells={len(opt)})")


if __name__ == "__main__":
    main()
