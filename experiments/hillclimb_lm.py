"""Hillclimb harness: re-lower a dry-run cell with config/rule overrides
and report roofline terms + byte breakdown. Usage:
  python experiments/hillclimb_lm.py <arch> <shape> <tag> [k=v ...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import ast, sys, json
from repro.launch.dryrun import run_cell

def parse(v):
    try:
        return ast.literal_eval(v)
    except Exception:
        return v

arch, shape, tag = sys.argv[1], sys.argv[2], sys.argv[3]
overrides, rules = {}, None
for kv in sys.argv[4:]:
    k, v = kv.split("=", 1)
    if k.startswith("rule."):
        from repro.parallel.sharding import LOGICAL_RULES
        rules = dict(LOGICAL_RULES) if rules is None else rules
        rules[k[5:]] = parse(v)
    else:
        overrides[k] = parse(v)
r = run_cell(arch, shape, multi_pod=False, outdir="experiments/hillclimb",
             overrides=overrides or None, rules=rules, tag=tag)
if r["status"] == "ok":
    t = r["roofline"]
    print(json.dumps({"tag": tag, "comp": t["compute_s"], "mem": t["memory_s"],
                      "coll": t["collective_s"], "bound": t["step_s_lower_bound"],
                      "by_op": {k: round(v/1e9,1) for k,v in r["bytes_by_op_unscaled"].items()}}, indent=1))
